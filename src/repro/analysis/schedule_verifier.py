"""Static schedule verifier: prove a built schedule correct, per engine.

The paper's claims are *structural* — NAP removes duplicate inter-node
messages, MLA bounds the bytes any chip pushes across the slow domain —
and this module proves those structures hold for **any** schedule a
registered engine builds, instead of spot-checking each engine with
bespoke example tests.  Four passes, each an independent re-derivation
that does not trust the schedules' own accounting helpers:

``match``
    Match-completeness of the message endpoints: every send has exactly
    one matching receive (each chip at most once as source and once as
    destination per round — the partial-permutation contract of the
    ``lax.ppermute`` lowering), no orphan receives (a ``recv_chips``
    mask entry with no message behind it folds garbage), no duplicate
    ``(src, dst)`` message within a step, indices in range, fractions
    in ``(0, 1]``.

``deadlock``
    Deadlock-freedom: the ``P2PStep.dep`` chains plus per-chip,
    per-domain (ICI vs DCI) port ordering must form a DAG consistent
    with emission order.  Cycle detection reports a counterexample
    trace; a forward dep (``dep >= index``) breaks the replay contract
    and is flagged even when no port cycle closes.

``reduction``
    Reduction correctness by symbolic contribution dataflow: each
    chip's state is an integer *count per original contributor* (per
    element for striped engines), folded through every message of the
    schedule.  The postcondition — every chip ends holding every chip's
    contribution **exactly once** — catches duplicates (the precise bug
    class the paper eliminates: a duplicated inter-node message double
    counts a node partial) and drops symmetrically.  ``mla_rs`` /
    ``mla_ag`` get ownership postconditions instead: the RS output
    blocks tile the payload with exactly-once contributions at each
    owner.  The symbolic counts are cross-checked against the NumPy
    replay oracles (``napalg.simulate_allreduce`` /
    ``simulate_mla_allreduce``) on random integer payloads.

``bytes``
    Byte-accounting equality: per-chip inter-node bytes are recomputed
    from the raw endpoint stream (:func:`repro.core.napalg.iter_messages`)
    and must agree with (a) the schedule's own
    ``max_internode_bytes_per_chip`` helper, (b) the simulator's replay
    accounting (:func:`repro.core.simulator.replay_internode_bytes`),
    and (c) the engine's *declared* bound —
    ``napalg.mla_internode_lower_bound`` for the striped allreduce, the
    one-way ``rs``/``ag`` bounds for the halves — rather than trusting
    any one of them.

Entry points: :func:`verify_schedule` (any schedule object),
:func:`verify_spec` (a registered :class:`repro.core.comm.EngineSpec`,
duck-typed so this module never imports ``comm``), and the grid-matrix
sweep :func:`verify_spec_grid`.  ``comm.verify_engine`` and the
``python -m repro.analysis`` driver are thin wrappers over these.
"""

from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Iterable, Sequence

import numpy as np


class _LazyModule:
    """Deferred import of ``repro.core.napalg``.

    ``repro.core.__init__`` imports ``comm`` (alphabetically) before
    ``napalg``, and ``comm`` imports this module for verify-on-register
    — an eager ``from ..core import napalg`` here would re-enter that
    half-initialized boot and blow up whichever side imported first.
    Deferring to first attribute access breaks the cycle for both entry
    orders; by the time any verifier function runs, ``napalg`` is fully
    loaded (``comm`` itself imports it before registering anything).
    """

    def __init__(self, name: str):
        self._name = name

    def __getattr__(self, attr):
        mod = importlib.import_module(self._name)
        self.__dict__.update(mod.__dict__)  # short-circuit next access
        return getattr(mod, attr)


napalg = _LazyModule("repro.core.napalg")

__all__ = [
    "Violation",
    "VerificationReport",
    "verify_schedule",
    "verify_spec",
    "verify_spec_grid",
    "build_spec_schedule",
    "GRID_MATRIX",
    "PAYLOAD_ELEMS",
    "REGISTER_GRIDS",
    "STRIPED_KINDS",
    "RULES",
]

RULES = ("match", "deadlock", "reduction", "bytes")

#: schedule kinds whose messages carry payload *fractions* derived from
#: the ragged stripe geometry (element-exact dataflow applies)
STRIPED_KINDS = frozenset({"mla", "mla_pipelined", "mla_rs", "mla_ag"})

#: the default verification grid matrix: degenerate grids (``n=1``,
#: ``ppn=1``), prime node counts, a power grid and mixed shapes — the
#: shapes where balanced-subgroup raggedness, donor rounds and uneven
#: blocks all differ structurally.
GRID_MATRIX = (
    (1, 1), (1, 4), (2, 1), (2, 2), (3, 1), (3, 2), (3, 3), (4, 4),
    (5, 2), (5, 4), (7, 3), (8, 4), (13, 2), (13, 4), (16, 4),
)

#: payload element counts swept per grid: ``None`` is the even
#: (divisibility-ideal) accounting, the rest are ragged (prime or
#: otherwise non-divisible) sizes including the 1-element degenerate.
PAYLOAD_ELEMS = (None, 1, 7, 96, 193)

#: the small grid set verify-on-register proves every new engine on
#: (one ragged prime grid, one power grid; cheap enough for import time)
REGISTER_GRIDS = ((2, 2), (3, 2), (5, 3))

_REL_TOL = 1e-6  # float fraction accounting tolerance (pytest.approx's)


@dataclasses.dataclass(frozen=True)
class Violation:
    """One invariant violation found by a verifier pass."""

    rule: str
    message: str

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(f"unknown rule {self.rule!r}; one of {RULES}")


@dataclasses.dataclass(frozen=True)
class VerificationReport:
    """The result of verifying one (engine, grid, payload) cell."""

    engine: str
    collective: str
    n_nodes: int
    ppn: int
    elems: int | None
    chunks: int
    checked: tuple[str, ...] = ()
    violations: tuple[Violation, ...] = ()
    notes: tuple[str, ...] = ()

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_row(self) -> dict:
        """JSON-safe row for the ``BENCH_7.json`` verification table."""
        return {
            "engine": self.engine,
            "collective": self.collective,
            "n": self.n_nodes,
            "ppn": self.ppn,
            "elems": self.elems,
            "chunks": self.chunks,
            "checked": list(self.checked),
            "ok": self.ok,
            "violations": [
                {"rule": v.rule, "message": v.message}
                for v in self.violations
            ],
            "notes": list(self.notes),
        }


# ---------------------------------------------------------------------------
# pass 1: match-completeness
# ---------------------------------------------------------------------------


def check_match(schedule) -> list[Violation]:
    """Endpoint matching: permutation validity, orphans, dup messages."""
    out: list[Violation] = []
    n_chips = schedule.n_chips

    def bad(msg: str) -> None:
        out.append(Violation("match", msg))

    if isinstance(schedule, napalg.NapSchedule):
        for i, step in enumerate(schedule.steps):
            step_dsts: set[int] = set()
            step_pairs: set[tuple[int, int]] = set()
            for rnd_idx, rnd in enumerate(step.rounds):
                srcs: set[int] = set()
                dsts: set[int] = set()
                for src, dst in rnd:
                    if not (0 <= src < n_chips and 0 <= dst < n_chips):
                        bad(
                            f"step {i} round {rnd_idx}: endpoint "
                            f"({src}, {dst}) outside [0, {n_chips})"
                        )
                        continue
                    if src == dst:
                        bad(f"step {i} round {rnd_idx}: self-send on chip {src}")
                    if src in srcs:
                        bad(
                            f"step {i} round {rnd_idx}: chip {src} sends "
                            "twice in one round (not a partial permutation)"
                        )
                    if dst in dsts:
                        bad(
                            f"step {i} round {rnd_idx}: chip {dst} receives "
                            "twice in one round (not a partial permutation)"
                        )
                    if (src, dst) in step_pairs:
                        bad(
                            f"step {i}: duplicate message {src}->{dst} "
                            "(duplicate inter-node payload)"
                        )
                    srcs.add(src)
                    dsts.add(dst)
                    step_pairs.add((src, dst))
                dup = dsts & step_dsts
                for d in sorted(dup):
                    bad(
                        f"step {i}: chip {d} receives in more than one "
                        "round (double-counted partial)"
                    )
                step_dsts |= dsts
            declared = set(step.recv_chips)
            for orphan in sorted(declared - step_dsts):
                bad(
                    f"step {i}: recv_chips lists chip {orphan} but no "
                    "message delivers to it (orphan recv — the fold "
                    "mask would admit garbage)"
                )
            for orphan in sorted(step_dsts - declared):
                bad(
                    f"step {i}: message delivers to chip {orphan} but "
                    "recv_chips omits it (orphan send — the payload "
                    "would be dropped by the fold mask)"
                )
            if len(step.recv_chips) != len(declared):
                bad(f"step {i}: recv_chips contains duplicates")
            for c in step.self_chips:
                if not 0 <= c < n_chips:
                    bad(f"step {i}: self chip {c} outside [0, {n_chips})")
        return out

    for i, step in enumerate(schedule.steps):
        fracs = step.pair_fracs()
        if len(fracs) != len(step.pairs):
            bad(
                f"step {i}: {len(step.pairs)} pairs but {len(fracs)} "
                "fractions"
            )
            continue
        srcs: set[int] = set()
        dsts: set[int] = set()
        pairs_seen: set[tuple[int, int]] = set()
        for (src, dst), f in zip(step.pairs, fracs):
            if not (0 <= src < n_chips and 0 <= dst < n_chips):
                bad(f"step {i}: endpoint ({src}, {dst}) outside [0, {n_chips})")
                continue
            if src == dst:
                bad(f"step {i}: self-send on chip {src}")
            if src in srcs:
                bad(
                    f"step {i}: chip {src} sends twice in one step "
                    "(not a partial permutation)"
                )
            if dst in dsts:
                bad(
                    f"step {i}: chip {dst} receives twice in one step "
                    "(not a partial permutation)"
                )
            if (src, dst) in pairs_seen:
                bad(f"step {i}: duplicate message {src}->{dst}")
            if not (0.0 < f <= 1.0 + 1e-9):
                bad(
                    f"step {i}: message {src}->{dst} carries fraction "
                    f"{f!r} outside (0, 1]"
                )
            srcs.add(src)
            dsts.add(dst)
            pairs_seen.add((src, dst))
    return out


# ---------------------------------------------------------------------------
# pass 2: deadlock-freedom
# ---------------------------------------------------------------------------


def check_deadlock(schedule) -> list[Violation]:
    """``dep`` chains + per-chip/per-domain port order must form a DAG."""
    if isinstance(schedule, napalg.NapSchedule):
        # NAP steps (and rounds within them) execute strictly in
        # sequence — the dependency order is the emission order, acyclic
        # by construction.
        return []

    out: list[Violation] = []
    n_steps = len(schedule.steps)
    ppn = schedule.ppn
    edges: dict[int, set[int]] = {i: set() for i in range(n_steps)}
    edge_kind: dict[tuple[int, int], str] = {}

    for i, step in enumerate(schedule.steps):
        dep = step.dep
        if dep < -1 or dep >= n_steps:
            out.append(
                Violation(
                    "deadlock",
                    f"step {i}: dep {dep} outside [-1, {n_steps})",
                )
            )
            continue
        if dep == i:
            out.append(Violation("deadlock", f"step {i} depends on itself"))
            continue
        if dep >= 0:
            edges[dep].add(i)
            edge_kind[(dep, i)] = "dep"
            if dep > i:
                # a forward dep breaks the replay contract (the
                # event-driven replay resolves deps in emission order)
                # even when no port cycle closes through it
                out.append(
                    Violation(
                        "deadlock",
                        f"step {i} depends on later step {dep} "
                        "(forward dep: replay order cannot satisfy it)",
                    )
                )

    # port-order edges: steps touching the same (chip, domain) port
    # serialize in emission order
    last_use: dict[tuple[int, bool], int] = {}
    for i, step in enumerate(schedule.steps):
        for src, dst in step.pairs:
            inter = src // ppn != dst // ppn
            for chip in (src, dst):
                key = (chip, inter)
                prev = last_use.get(key)
                if prev is not None and prev != i:
                    edges[prev].add(i)
                    edge_kind.setdefault((prev, i), "port")
                last_use[key] = i

    # cycle detection (iterative DFS) with a counterexample trace
    WHITE, GREY, BLACK = 0, 1, 2
    color = [WHITE] * n_steps
    parent: dict[int, int] = {}
    for root in range(n_steps):
        if color[root] != WHITE:
            continue
        stack: list[tuple[int, Iterable[int]]] = [(root, iter(sorted(edges[root])))]
        color[root] = GREY
        while stack:
            node, it = stack[-1]
            advanced = False
            for nxt in it:
                if color[nxt] == GREY:
                    # unwind the counterexample trace nxt -> ... -> node -> nxt
                    trace = [node]
                    cur = node
                    while cur != nxt:
                        cur = parent[cur]
                        trace.append(cur)
                    trace.reverse()
                    trace.append(node)
                    arcs = " -> ".join(
                        f"step {a} ({edge_kind.get((a, b), 'port')})"
                        for a, b in zip(trace, trace[1:])
                    )
                    out.append(
                        Violation(
                            "deadlock",
                            "dependency cycle: "
                            + arcs
                            + f" -> step {trace[-1]}",
                        )
                    )
                    return out
                if color[nxt] == WHITE:
                    color[nxt] = GREY
                    parent[nxt] = node
                    stack.append((nxt, iter(sorted(edges[nxt]))))
                    advanced = True
                    break
            if not advanced:
                color[node] = BLACK
                stack.pop()
    return out


# ---------------------------------------------------------------------------
# pass 3: reduction correctness (symbolic contribution dataflow)
# ---------------------------------------------------------------------------


def _local_counts(counts: np.ndarray, n_nodes: int, ppn: int) -> np.ndarray:
    """Intra-node allreduce over a (n_chips, n_chips) count matrix."""
    m = counts.reshape(n_nodes, ppn, -1)
    m = np.broadcast_to(m.sum(axis=1, keepdims=True), m.shape)
    return m.reshape(counts.shape).copy()


def nap_contribution_counts(schedule: napalg.NapSchedule) -> np.ndarray:
    """Symbolic dataflow over a NAP schedule.

    ``counts[chip, contributor]`` after the final intra-node allreduce;
    a correct schedule yields the all-ones matrix: every chip holds
    every chip's contribution exactly once.
    """
    n, ppn = schedule.n_nodes, schedule.ppn
    n_chips = n * ppn
    counts = _local_counts(np.eye(n_chips, dtype=np.int64), n, ppn)
    for step in schedule.steps:
        snap = counts
        contrib = np.zeros_like(counts)
        for src, dst in step.messages:
            contrib[dst] += snap[src]
        for chip in step.self_chips:
            contrib[chip] += snap[chip]
        counts = _local_counts(contrib, n, ppn)
    return counts


def p2p_contribution_counts(schedule: napalg.P2PSchedule) -> np.ndarray:
    """Symbolic dataflow over a whole-payload P2P schedule (rd/smp/...).

    ``combine=True`` folds the sender's pre-step counts into the
    receiver's; ``combine=False`` *replaces* the receiver's counts (the
    broadcast/return semantics of the executed lowering).
    """
    n_chips = schedule.n_chips
    counts = np.eye(n_chips, dtype=np.int64)
    for step in schedule.steps:
        snap = counts.copy()
        for src, dst in step.pairs:
            if step.combine:
                counts[dst] = counts[dst] + snap[src]
            else:
                counts[dst] = snap[src]
    return counts


def striped_contribution_counts(
    n_nodes: int, ppn: int, elems: int, chunks: int = 1
) -> np.ndarray:
    """Element-exact contribution dataflow of the striped (MLA) engines.

    Walks the exact ragged chunk -> stripe -> block geometry the
    schedule's per-pair fractions are derived from (the ``bytes`` pass
    proves that derivation byte-exact against the schedule itself) with
    integer contribution counters: returns
    ``counts[chip, contributor, elem]``, all-ones iff every chip ends
    holding every contribution of every element exactly once.
    """
    n_chips = n_nodes * ppn
    counts = np.zeros((n_chips, n_chips, elems), dtype=np.int16)
    counts[np.arange(n_chips), np.arange(n_chips), :] = 1
    out = np.zeros_like(counts)
    c_off = 0
    for ce in napalg.ragged_splits(elems, max(1, chunks)):
        if ce == 0:
            continue
        stripes, blocks = napalg.mla_stripe_geometry(n_nodes, ppn, ce)
        s_off = c_off
        for r, sr in enumerate(stripes):
            if sr == 0:
                continue
            sl = slice(s_off, s_off + sr)
            # phase 1 (intra RS): lane-r chip of node j holds node j's
            # stripe partial
            node_part = np.stack(
                [
                    counts[j * ppn : (j + 1) * ppn, :, sl].sum(
                        axis=0, dtype=np.int16
                    )
                    for j in range(n_nodes)
                ]
            )
            # phase 2 (per-lane inter RS): node j reduces its sub-block
            reduced = np.zeros((n_chips, sr), dtype=np.int16)
            b_off = 0
            for bj in blocks[r]:
                if bj:
                    reduced[:, b_off : b_off + bj] = node_part[
                        :, :, b_off : b_off + bj
                    ].sum(axis=0, dtype=np.int16)
                    b_off += bj
            # phases 3/4 (inter + intra AG): every chip gets the stripe
            out[:, :, sl] = reduced[None, :, :]
            s_off += sr
        c_off += ce
    return out


def rs_ownership(
    n_nodes: int, ppn: int, elems: int
) -> tuple[np.ndarray, np.ndarray]:
    """RS postcondition state: ``(owner, counts)``.

    ``owner[elem]`` is the chip that ends holding element ``elem``'s
    fully reduced block (chip ``(node j, lane r)`` owns block ``(r, j)``
    of the stripe geometry); ``counts[contributor, elem]`` are the
    contribution counts at that owner.
    """
    n_chips = n_nodes * ppn
    owner = np.full(elems, -1, dtype=np.int64)
    counts = np.zeros((n_chips, elems), dtype=np.int16)
    stripes, blocks = napalg.mla_stripe_geometry(n_nodes, ppn, elems)
    s_off = 0
    for r, sr in enumerate(stripes):
        b_off = s_off
        for j, bj in enumerate(blocks[r]):
            if bj:
                chip = j * ppn + r
                owner[b_off : b_off + bj] = chip
                counts[:, b_off : b_off + bj] += 1
                b_off += bj
        s_off += sr
    return owner, counts


def _defect_triples(counts: np.ndarray, limit: int = 3) -> str:
    bad = np.argwhere(counts != 1)
    shown = ", ".join(
        f"{tuple(int(v) for v in idx)}: count {int(counts[tuple(idx)])}"
        for idx in bad[:limit]
    )
    more = f" (+{len(bad) - limit} more)" if len(bad) > limit else ""
    return shown + more


def check_reduction(
    schedule,
    *,
    collective: str = "allreduce",
    elems: int | None = None,
    chunks: int = 1,
) -> list[Violation]:
    """Symbolic contribution-set dataflow per chip per step.

    Proves every chip ends holding every chip's contribution exactly
    once (allreduce), or the RS/AG ownership postconditions, and
    cross-checks the symbolic counts against the NumPy replay oracles.
    """
    out: list[Violation] = []
    n, ppn = schedule.n_nodes, schedule.ppn
    n_chips = n * ppn
    rng = np.random.default_rng(n * 1009 + ppn)

    def bad(msg: str) -> None:
        out.append(Violation("reduction", msg))

    if isinstance(schedule, napalg.NapSchedule):
        counts = nap_contribution_counts(schedule)
        if not (counts == 1).all():
            dup = int((counts > 1).sum())
            drop = int((counts == 0).sum())
            bad(
                f"{dup} duplicated and {drop} dropped contributions; "
                "defect (chip, contributor) cells: "
                + _defect_triples(counts)
            )
        # cross-check the symbolic counts against the numeric replay
        vals = rng.integers(1, 97, size=(n_chips, 3)).astype(np.float64)
        predicted = counts.astype(np.float64) @ vals
        replayed = napalg.simulate_allreduce(schedule, vals)
        if not np.array_equal(predicted, replayed):
            bad(
                "symbolic contribution counts disagree with the "
                "simulate_allreduce replay (verifier/oracle drift)"
            )
        return out

    kind = getattr(schedule, "kind", "generic")
    if kind in ("mla", "mla_pipelined"):
        e = elems if elems is not None else n_chips
        counts = striped_contribution_counts(n, ppn, e, chunks)
        if not (counts == 1).all():
            dup = int((counts > 1).sum())
            drop = int((counts == 0).sum())
            bad(
                f"{dup} duplicated and {drop} dropped contributions; "
                "defect (chip, contributor, elem) cells: "
                + _defect_triples(counts)
            )
        vals = rng.integers(1, 97, size=(n_chips, e)).astype(np.float64)
        predicted = np.einsum("pce,ce->pe", counts.astype(np.float64), vals)
        replayed = napalg.simulate_mla_allreduce(
            n, ppn, vals, chunks=max(1, chunks)
        )
        if not np.array_equal(predicted, replayed):
            bad(
                "symbolic contribution counts disagree with the "
                "simulate_mla_allreduce replay (verifier/oracle drift)"
            )
        return out

    if kind == "mla_rs":
        e = elems if elems is not None else n_chips
        owner, counts = rs_ownership(n, ppn, e)
        if (owner < 0).any():
            bad(
                f"{int((owner < 0).sum())} elements of {e} have no "
                "owning chip (RS output blocks do not tile the payload)"
            )
        if not (counts == 1).all():
            bad(
                "RS owners do not hold every contribution exactly "
                "once; defect (contributor, elem) cells: "
                + _defect_triples(counts)
            )
        return out

    if kind == "mla_ag":
        e = elems if elems is not None else n_chips
        owner, _ = rs_ownership(n, ppn, e)
        if (owner < 0).any():
            bad(
                f"{int((owner < 0).sum())} elements of {e} have no "
                "owner in the AG input partition"
            )
        counts_o = np.bincount(owner[owner >= 0], minlength=n_chips)
        stripes, blocks = napalg.mla_stripe_geometry(n, ppn, e)
        for j in range(n):
            for r in range(ppn):
                want = blocks[r][j]
                got = int(counts_o[j * ppn + r])
                if got != want:
                    bad(
                        f"chip ({j}, {r}) owns {got} elements, stripe "
                        f"geometry says {want}"
                    )
        return out

    # whole-payload P2P schedules (rd / smp / generic): fractions must
    # be 1.0 for the multiset semantics to apply — anything fractional
    # of an unknown kind is *unverifiable*, which is a violation, not a
    # vacuous pass.
    fractional = [
        m for m in napalg.iter_messages(schedule) if m.frac != 1.0
    ]
    if fractional:
        m = fractional[0]
        bad(
            f"schedule kind {kind!r} carries fractional payloads (e.g. "
            f"step {m.step} {m.src}->{m.dst} frac {m.frac:.4g}) but "
            "declares no striped kind the verifier can prove; register "
            "it with a known kind or extend the verifier"
        )
        return out
    counts = p2p_contribution_counts(schedule)
    if not (counts == 1).all():
        dup = int((counts > 1).sum())
        drop = int((counts == 0).sum())
        bad(
            f"{dup} duplicated and {drop} dropped contributions; "
            "defect (chip, contributor) cells: " + _defect_triples(counts)
        )
    return out


# ---------------------------------------------------------------------------
# pass 4: byte-accounting equality
# ---------------------------------------------------------------------------


def endpoint_internode_bytes(schedule, s: float) -> np.ndarray:
    """Per-chip inter-node bytes recomputed from the raw endpoint
    stream — the verifier's own accounting, independent of the
    schedules' helpers and the simulator's replay."""
    sends = np.zeros(schedule.n_chips, dtype=np.float64)
    for m in napalg.iter_messages(schedule):
        if m.inter:
            sends[m.src] += m.frac * s
    return sends


def _expected_striped_bytes(
    kind: str, n: int, ppn: int, elems: int, chunks: int, s: float
) -> np.ndarray:
    """Geometry-derived per-chip inter-node bytes for striped engines."""
    ways = 2.0 if kind in ("mla", "mla_pipelined") else 1.0
    sends = np.zeros(n * ppn, dtype=np.float64)
    per_elem = s / float(max(elems, 1))
    for ce in napalg.ragged_splits(elems, max(1, chunks)):
        if ce == 0:
            continue
        stripes, blocks = napalg.mla_stripe_geometry(n, ppn, ce)
        for j in range(n):
            for r in range(ppn):
                sends[j * ppn + r] += (
                    ways * (stripes[r] - blocks[r][j]) * per_elem
                )
    return sends


#: engine kind -> napalg bound-function name (resolved at use so module
#: import stays lazy, see ``_LazyModule``)
_STRIPED_BOUND_NAMES = {
    "mla": "mla_internode_lower_bound",
    "mla_rs": "rs_internode_lower_bound",
    "mla_ag": "ag_internode_lower_bound",
}


def check_bytes(
    schedule,
    *,
    elems: int | None = None,
    chunks: int = 1,
    itemsize: float = 4.0,
) -> list[Violation]:
    """Recompute per-chip inter-node bytes from the schedule itself and
    require equality with the accounting helpers, the simulator replay
    and the engine's declared bound."""
    from ..core import simulator

    out: list[Violation] = []
    n, ppn = schedule.n_nodes, schedule.ppn

    def bad(msg: str) -> None:
        out.append(Violation("bytes", msg))

    e = elems
    s = float((e if e is not None else n * ppn) * itemsize)
    atol = _REL_TOL * max(s, 1.0)

    computed = endpoint_internode_bytes(schedule, s)

    helper = float(schedule.max_internode_bytes_per_chip(s))
    if not math.isclose(
        computed.max(initial=0.0), helper, rel_tol=_REL_TOL, abs_tol=atol
    ):
        bad(
            f"endpoint recomputation gives max {computed.max(initial=0.0):.6g} "
            f"inter-node bytes/chip but max_internode_bytes_per_chip "
            f"reports {helper:.6g}"
        )

    replayed = simulator.replay_internode_bytes(schedule, s)
    if not np.allclose(computed, replayed, rtol=_REL_TOL, atol=atol):
        worst = int(np.argmax(np.abs(computed - replayed)))
        bad(
            f"endpoint recomputation disagrees with the simulator "
            f"replay accounting (chip {worst}: {computed[worst]:.6g} vs "
            f"{replayed[worst]:.6g})"
        )

    if isinstance(schedule, napalg.NapSchedule):
        # NAP messages each carry the full payload: per-chip bytes are
        # (messages sent) x s, already proven equal to the helper above;
        # additionally the declared shape bound: nobody sends more
        # rounds than exist.
        max_rounds = sum(len(st.rounds) for st in schedule.steps)
        if computed.max(initial=0.0) > max_rounds * s + atol:
            bad(
                "a chip sends more inter-node bytes than one full "
                "payload per round"
            )
        return out

    kind = getattr(schedule, "kind", "generic")
    if kind in STRIPED_KINDS:
        ways = 2.0 if kind in ("mla", "mla_pipelined") else 1.0
        if e is None:
            # even (divisibility-ideal) accounting: the builder keeps
            # raw butterfly weights, so chips of nodes that skip steps
            # (non-power node counts) send *less* — the per-chip vector
            # is non-uniform.  The binding chip (node 0 participates in
            # every step) must hit the divisible-stripe closed form
            # exactly.
            expect_max = ways * (s / ppn) * (n - 1) / n
            if not math.isclose(
                computed.max(initial=0.0), expect_max,
                rel_tol=_REL_TOL, abs_tol=atol,
            ):
                bad(
                    f"max inter-node bytes/chip "
                    f"{computed.max(initial=0.0):.6g} != even-stripe "
                    f"closed form {expect_max:.6g}"
                )
            return out
        expected = _expected_striped_bytes(kind, n, ppn, e, chunks, s)
        if not np.allclose(computed, expected, rtol=_REL_TOL, atol=atol):
            worst = int(np.argmax(np.abs(computed - expected)))
            bad(
                f"per-chip bytes diverge from the ragged stripe "
                f"geometry (chip {worst}: schedule {computed[worst]:.6g} "
                f"vs geometry {expected[worst]:.6g})"
            )
        bound_name = _STRIPED_BOUND_NAMES.get(kind)
        if bound_name is not None:
            declared = getattr(napalg, bound_name)(n, ppn, e) * itemsize
            if not math.isclose(
                computed.max(initial=0.0), declared,
                rel_tol=_REL_TOL, abs_tol=atol,
            ):
                bad(
                    f"max inter-node bytes/chip "
                    f"{computed.max(initial=0.0):.6g} != declared "
                    f"uneven-block bound {declared:.6g}"
                )
        else:  # mla_pipelined: chunking may not beat the bound
            floor = (
                napalg.mla_internode_lower_bound(n, ppn, e) * itemsize
            )
            if computed.max(initial=0.0) < floor - atol:
                bad(
                    f"max inter-node bytes/chip "
                    f"{computed.max(initial=0.0):.6g} below the "
                    f"uneven-block lower bound {floor:.6g} "
                    "(accounting must be wrong: no schedule beats it)"
                )
    return out


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def verify_schedule(
    schedule,
    *,
    engine: str = "",
    collective: str = "allreduce",
    elems: int | None = None,
    chunks: int = 1,
    itemsize: float = 4.0,
) -> VerificationReport:
    """Run all four verifier passes over one built schedule."""
    violations: list[Violation] = []
    violations += check_match(schedule)
    violations += check_deadlock(schedule)
    violations += check_reduction(
        schedule, collective=collective, elems=elems, chunks=chunks
    )
    violations += check_bytes(
        schedule, elems=elems, chunks=chunks, itemsize=itemsize
    )
    return VerificationReport(
        engine=engine or getattr(schedule, "kind", "?"),
        collective=collective,
        n_nodes=schedule.n_nodes,
        ppn=schedule.ppn,
        elems=elems,
        chunks=chunks,
        checked=RULES,
        violations=tuple(violations),
    )


def build_spec_schedule(spec, n_nodes: int, ppn: int, *, chunks: int = 1,
                        elems: int | None = None):
    """Build the schedule an engine spec executes, from its declared
    calling-convention flags (mirrors ``comm.engine_schedule`` without
    importing ``comm`` — the registry calls into this module at import
    time, so the dependency must point one way only)."""
    if spec.build_schedule is None:
        return None
    if spec.chunked:
        return spec.build_schedule(n_nodes, ppn, max(1, chunks), elems)
    if spec.ragged:
        return spec.build_schedule(n_nodes, ppn, elems)
    return spec.build_schedule(n_nodes, ppn)


def verify_spec(
    spec,
    n_nodes: int,
    ppn: int,
    *,
    elems: int | None = None,
    chunks: int = 1,
    itemsize: float = 4.0,
) -> VerificationReport:
    """Verify one registered engine spec on one grid/payload cell.

    ``spec`` is duck-typed (``name`` / ``collective`` / ``min_nodes`` /
    ``min_ppn`` / ``build_schedule`` / ``chunked`` / ``ragged``) so
    this module never imports the registry.  Engines below their
    declared grid minimum are reported as skipped (the dispatcher never
    sends them there); engines without a schedule builder are reported
    as native single-collective lowerings with nothing to verify.
    """
    base = dict(
        engine=spec.name, collective=spec.collective,
        n_nodes=n_nodes, ppn=ppn, elems=elems,
        chunks=chunks if spec.chunked else 1,
    )
    if n_nodes < spec.min_nodes or ppn < spec.min_ppn:
        return VerificationReport(
            **base,
            notes=(
                f"skipped: grid below engine minimum "
                f"(min_nodes={spec.min_nodes}, min_ppn={spec.min_ppn})",
            ),
        )
    if spec.build_schedule is None:
        return VerificationReport(
            **base,
            notes=(
                "native: engine lowers to a single native collective "
                "(no message schedule to verify)",
            ),
        )
    try:
        schedule = build_spec_schedule(
            spec, n_nodes, ppn,
            chunks=chunks if spec.chunked else 1, elems=elems,
        )
    except Exception as exc:  # builder crash IS a verification failure
        return VerificationReport(
            **base,
            checked=("match",),
            violations=(
                Violation(
                    "match",
                    f"schedule builder crashed: {type(exc).__name__}: {exc}",
                ),
            ),
        )
    return verify_schedule(
        schedule,
        engine=spec.name,
        collective=spec.collective,
        elems=elems,
        chunks=chunks if spec.chunked else 1,
        itemsize=itemsize,
    )


def verify_spec_grid(
    spec,
    grids: Sequence[tuple[int, int]] = GRID_MATRIX,
    payloads: Sequence[int | None] = PAYLOAD_ELEMS,
    *,
    chunk_depths: Sequence[int] = (1, 2, 3),
) -> list[VerificationReport]:
    """Sweep one engine spec over a grid x payload (x chunks) matrix."""
    reports = []
    depths = list(chunk_depths) if spec.chunked else [1]
    for n, ppn in grids:
        for elems in payloads:
            for chunks in depths:
                reports.append(
                    verify_spec(
                        spec, n, ppn, elems=elems, chunks=chunks
                    )
                )
    return reports
