"""Layer 0 of the proof chain: exhaustive protocol model checking for
the serving control plane.

The schedule verifier (layer 1), SPMD jaxpr lint (layer 2) and HLO
wire-lint (layer 3) prove everything *below* the decode-step boundary.
This module extends the chain downward to the host protocol that fires
those collectives: an explicit-state, bounded exhaustive model checker
that drives the **real** control-plane objects —
:class:`repro.serve.scheduler.Scheduler`,
:class:`repro.serve.router.Router`,
:class:`repro.runtime.fault.ReplicaHealth` /
:class:`~repro.runtime.fault.StragglerMonitor` — through every
interleaving of a nondeterministic event alphabet, with no re-modeling:
a checker bug cannot hide a product bug behind an idealized model,
because there is no model.

Event alphabet (one event = one atomic control-plane call, exactly what
the engine / router / driver perform between decode slices)::

    ("submit",)          router.submit() — admission or backpressure-reject
    ("admit", r)         decode-step boundary admission on replica r
    ("token", r, s)      one generated (non-EOS) token for slot s
    ("eos", r, s)        EOS token for slot s (early finish)
    ("evict", k, r)      cancel submission k through replica r's registry
    ("degrade", r)       straggler signal -> ReplicaHealth degraded (+ reroute)
    ("recover", r)       one clean step toward recovery hysteresis
    ("reroute", r)       explicit router.reroute of a degraded replica
    ("loss", r)          replica death -> router.fail_replica re-plan

State-space machinery:

* **canonical state hashing** — worlds are deduped by a canonical tuple
  with *symmetry reduction over request ids*: live requests are
  renumbered in structural scan order (replica index, queue position,
  slot index), so states that differ only by rid relabeling merge; and
  **terminal collapse**: finished/evicted/rejected requests have no
  future protocol behavior, so they fold into per-class counts.
* **breadth-first exploration** — the first counterexample found is at
  minimal event depth, then :func:`shrink_trace` delta-debugs it to a
  locally-minimal replayable trace.

At every reachable state the checker asserts **safety**:

* conservation — each submitted rid is in exactly one of
  queued/active/finished/evicted/rejected across **all** replicas, and
  sits in exactly the container its state names;
* ownership — a live rid is registered with exactly one replica (a
  stale second registry entry is how evict races a reroute);
* slot accounting — ``Scheduler.check_invariants`` at every state;
* FIFO — admission takes exactly the queue head into the lowest free
  slot, and no queue is ever reordered by a reroute/drain;
* acceptance is binding — a request that was ever QUEUED is never
  later REJECTED (backpressure happens at submit, not mid-flight);
* placement — the router's ``placement`` map points at the replica
  actually holding each live request;
* silence after terminal states — a terminal request's token list
  never grows, its slot is released, its remaining budget is 0;
* hysteresis — ``ReplicaHealth`` recovers after exactly ``recovery``
  consecutive clean steps, not one early or late;

and **quiescence-style liveness**: from every reachable state,
stop-admissions plus drain events (recover, admit, token) must reach
``idle`` — no stuck slot, no request stranded on a degraded or lost
replica.

Any violation is emitted as a minimal replayable event trace that
doubles as a pytest (:func:`assert_trace_clean` /
:func:`assert_trace_violates` replay it against fixed or seeded-buggy
control planes).

Quickstart::

    from repro.analysis import protocol_check as pc

    report = pc.check_protocol(pc.CheckConfig(replicas=2, slots=2,
                                              queue=1, requests=4))
    assert report.ok, report.violations[0].detail
    # a seeded bug is rejected with a replayable counterexample:
    bad = pc.check_protocol(cfg, scheduler_cls=LeakyScheduler)
    print(bad.violations[0].trace)   # paste into a regression test

The full small-scope grid sweep is ``python -m repro.analysis
--protocol`` (the ``BENCH_10.json`` CI gate).  This module imports
:mod:`repro.serve` only inside functions: the analysis package stays
jax-free at module scope.
"""

from __future__ import annotations

import dataclasses
import time
from collections import Counter

__all__ = [
    "CheckConfig",
    "CheckReport",
    "Violation",
    "World",
    "check_protocol",
    "run_trace",
    "shrink_trace",
    "quiesce",
    "assert_trace_clean",
    "assert_trace_violates",
    "verify_decode_geometry_link",
    "TraceNotApplicable",
]

# request lifecycle states, mirrored as literals so this module stays
# import-free at module scope (importing repro.serve pulls in jax via
# the engine); World.__init__ asserts they match the real constants
_QUEUED = "queued"
_ACTIVE = "active"
_FINISHED = "finished"
_EVICTED = "evicted"
_REJECTED = "rejected"

#: clean / straggling step durations fed to the real StragglerMonitor.
#: All clean steps are exactly the EWMA baseline, so the monitor's EWMA
#: is a constant of the exploration (straggler outliers are quarantined
#: by the monitor itself) and canonical hashing stays exact.
_CLEAN_DT = 1.0
_STRAGGLE_DT = 10.0


class TraceNotApplicable(Exception):
    """Raised when replaying an event that is not enabled in the
    current state (shrinking may produce such candidates)."""


class ProtocolError(Exception):
    """A named protocol-rule violation detected while applying an event."""

    def __init__(self, rule: str, detail: str):
        super().__init__(f"{rule}: {detail}")
        self.rule = rule
        self.detail = detail


@dataclasses.dataclass(frozen=True)
class CheckConfig:
    """Small-scope bounds for one exhaustive exploration."""

    replicas: int = 2        #: serving replicas behind the Router
    slots: int = 1           #: decode slots per replica
    queue: int | None = 1    #: per-replica max_queue (None = unbounded)
    requests: int = 3        #: total submission budget
    budgets: tuple[int, ...] = (2, 1)  #: max_new_tokens, cycled by index
    recovery: int = 2        #: ReplicaHealth recovery hysteresis
    eos_id: int = 7          #: EOS token id
    depth: int | None = None  #: max event depth (None = full closure)
    faults: bool = True      #: include degrade/recover/reroute events
    losses: bool = True      #: include replica-loss events (needs faults)
    liveness: bool = True    #: quiescence drain from every reachable state


@dataclasses.dataclass
class Violation:
    """One protocol violation with its replayable counterexample."""

    rule: str
    detail: str
    trace: tuple
    config: CheckConfig

    def to_row(self) -> dict:
        return {
            "rule": self.rule,
            "detail": self.detail,
            "trace": [list(e) for e in self.trace],
        }

    def pytest_snippet(self) -> str:
        """A paste-ready regression test replaying this trace."""
        events = ",\n        ".join(repr(e) for e in self.trace)
        cfg = ", ".join(
            f"{f.name}={getattr(self.config, f.name)!r}"
            for f in dataclasses.fields(self.config)
        )
        return (
            f"def test_regression_{self.rule.replace('-', '_')}():\n"
            f"    from repro.analysis import protocol_check as pc\n"
            f"    pc.assert_trace_clean(pc.CheckConfig({cfg}), (\n"
            f"        {events},\n"
            f"    ))  # violated {self.rule!r} before the fix\n"
        )


class _Replica:
    """The replica surface :class:`repro.serve.router.Router` documents
    (``submit`` / ``outstanding_tokens`` / ``scheduler``) over a real
    :class:`Scheduler` — the device plane abstracted to exactly its
    scheduler effects, the control plane fully real."""

    __slots__ = ("scheduler",)

    def __init__(self, scheduler):
        self.scheduler = scheduler

    def submit(self, prompt, max_new_tokens, **kw):
        return self.scheduler.submit(prompt, max_new_tokens, **kw)

    def outstanding_tokens(self):
        return self.scheduler.outstanding_tokens()

    @property
    def idle(self):
        return self.scheduler.idle


def _clone_request(req, memo):
    c = memo.get(req.rid)
    if c is None:
        c = object.__new__(type(req))
        c.__dict__.update(req.__dict__)
        c.generated = list(req.generated)
        c.token_times = list(req.token_times)
        memo[req.rid] = c
    return c


class World:
    """One explorable control-plane state: a real Router over real
    Schedulers with real health monitors, plus the checker's harness
    bookkeeping (submission order, acceptance, frozen token counts)."""

    def __init__(
        self,
        cfg: CheckConfig,
        *,
        scheduler_cls=None,
        router_cls=None,
        health_cls=None,
        monitor_cls=None,
        _blank: bool = False,
    ):
        from repro.runtime.fault import ReplicaHealth, StragglerMonitor
        from repro.serve import scheduler as _sched_mod
        from repro.serve.router import Router

        assert (_QUEUED, _ACTIVE, _FINISHED, _EVICTED, _REJECTED) == (
            _sched_mod.QUEUED, _sched_mod.ACTIVE, _sched_mod.FINISHED,
            _sched_mod.EVICTED, _sched_mod.REJECTED,
        )
        self.cfg = cfg
        self._scheduler_cls = scheduler_cls or _sched_mod.Scheduler
        self._router_cls = router_cls or Router
        self._health_cls = health_cls or ReplicaHealth
        self._monitor_cls = monitor_cls or StragglerMonitor
        if _blank:
            return
        replicas = [
            _Replica(
                self._scheduler_cls(
                    cfg.slots, max_queue=cfg.queue, eos_id=cfg.eos_id
                )
            )
            for _ in range(cfg.replicas)
        ]
        health = [
            self._health_cls(
                self._monitor_cls(threshold=2.0, alpha=0.5, warmup=1),
                recovery=cfg.recovery,
            )
            for _ in range(cfg.replicas)
        ]
        self.router = self._router_cls(replicas, health=health)
        self.lost: set[int] = set()
        self.submitted: list = []     # Request objects, submission order
        self.n_submitted = 0
        self.accepted: set[int] = set()   # rids that were ever QUEUED
        self.frozen: dict[int, int] = {}  # rid -> len(generated) at terminal
        self.trace: tuple = ()
        self._step_no = 0
        # pre-warm every straggler monitor past warmup with baseline
        # steps so degrade/recover signals are live from depth 0
        for r in range(cfg.replicas):
            for _ in range(2):
                self.router.observe_step(r, self._next_step(), _CLEAN_DT)

    # -- plumbing ----------------------------------------------------------

    def _next_step(self) -> int:
        self._step_no += 1
        return self._step_no

    def _sched(self, r: int):
        return self.router.replicas[r].scheduler

    def clone(self) -> "World":
        w = World(
            self.cfg,
            scheduler_cls=self._scheduler_cls,
            router_cls=self._router_cls,
            health_cls=self._health_cls,
            monitor_cls=self._monitor_cls,
            _blank=True,
        )
        memo: dict = {}
        replicas = [
            _Replica(self._clone_scheduler(rep.scheduler, memo))
            for rep in self.router.replicas
        ]
        health = [self._clone_health(h) for h in self.router.health]
        w.router = self._clone_router(self.router, replicas, health)
        w.lost = set(self.lost)
        w.submitted = [_clone_request(r, memo) for r in self.submitted]
        w.n_submitted = self.n_submitted
        w.accepted = set(self.accepted)
        w.frozen = dict(self.frozen)
        w.trace = self.trace
        w._step_no = self._step_no
        return w

    def _clone_scheduler(self, s, memo):
        from collections import deque

        c = type(s).__new__(type(s))
        c.num_slots = s.num_slots
        c.max_queue = s.max_queue
        c.buckets = s.buckets
        c.eos_id = s.eos_id
        c.queue = deque(_clone_request(r, memo) for r in s.queue)
        c.slots = [
            None if r is None else _clone_request(r, memo) for r in s.slots
        ]
        c._free = list(s._free)
        c._ids = s._ids  # the process-global id counter is shared
        c.requests = {
            rid: _clone_request(r, memo) for rid, r in s.requests.items()
        }
        c.n_rejected = s.n_rejected
        # mutation subclasses may carry extra (immutable) state
        for k, v in vars(s).items():
            if k not in vars(c):
                setattr(c, k, v)
        return c

    def _clone_health(self, h):
        m = h.monitor
        mc = type(m).__new__(type(m))
        mc.threshold, mc.alpha, mc.warmup = m.threshold, m.alpha, m.warmup
        mc.on_event = m.on_event
        mc.ewma, mc.count = m.ewma, m.count
        mc.events = list(m.events)
        hc = type(h).__new__(type(h))
        hc.monitor = mc
        hc.recovery = h.recovery
        hc.healthy = h.healthy
        hc._clean = h._clean
        hc.n_degraded = h.n_degraded
        for k, v in vars(h).items():
            if k not in vars(hc):
                setattr(hc, k, v)
        return hc

    def _clone_router(self, router, replicas, health):
        c = type(router).__new__(type(router))
        c.replicas = replicas
        c.health = health
        c.placement = dict(router.placement)
        c.n_rerouted = router.n_rerouted
        for k, v in vars(router).items():
            if k not in vars(c):
                setattr(c, k, set(v) if isinstance(v, set) else v)
        return c

    # -- event alphabet ----------------------------------------------------

    def enabled_events(self) -> list[tuple]:
        cfg = self.cfg
        out: list[tuple] = []
        if self.n_submitted < cfg.requests:
            out.append(("submit",))
        for r in range(cfg.replicas):
            if r in self.lost:
                continue
            s = self._sched(r)
            if s.queue and s.free_slots:
                out.append(("admit", r))
            for slot, req in enumerate(s.slots):
                if req is not None:
                    out.append(("token", r, slot))
                    if req.remaining >= 2:
                        out.append(("eos", r, slot))
        for k, req in enumerate(self.submitted):
            if req.done:
                continue
            for r in range(cfg.replicas):
                if req.rid in self._sched(r).requests:
                    out.append(("evict", k, r))
        if cfg.faults:
            alive = [r for r in range(cfg.replicas) if r not in self.lost]
            for r in alive:
                out.append(("degrade", r))
                h = self.router.health[r]
                if not h.healthy:
                    out.append(("recover", r))
                if not h.healthy and self._sched(r).queue:
                    out.append(("reroute", r))
                if cfg.losses and len(alive) >= 2:
                    out.append(("loss", r))
        return out

    def apply(self, ev: tuple) -> None:
        """Apply one event to the real objects, enforcing the event's
        protocol postconditions.  Raises :class:`ProtocolError` on a
        rule violation, :class:`TraceNotApplicable` when the event is
        not enabled (replay of a shrunk trace)."""
        kind = ev[0]
        self.trace = self.trace + (ev,)
        handler = getattr(self, f"_ev_{kind}", None)
        if handler is None:
            raise TraceNotApplicable(f"unknown event {ev!r}")
        handler(*ev[1:])

    def apply_checked(self, ev: tuple) -> Violation | None:
        """Apply one event; any crash or rule violation becomes a
        :class:`Violation` carrying the replayable trace."""
        try:
            self.apply(ev)
        except TraceNotApplicable:
            raise
        except ProtocolError as e:
            return Violation(e.rule, e.detail, self.trace, self.cfg)
        except Exception as e:  # a crash reachable via the public API
            return Violation(
                "crash",
                f"{type(e).__name__}: {e} (applying {ev!r})",
                self.trace,
                self.cfg,
            )
        return None

    def _require(self, ok: bool, why: str) -> None:
        if not ok:
            raise TraceNotApplicable(why)

    def _alive(self) -> list[int]:
        return [r for r in range(self.cfg.replicas) if r not in self.lost]

    def _ev_submit(self) -> None:
        self._require(
            self.n_submitted < self.cfg.requests, "submission budget spent"
        )
        k = self.n_submitted
        budget = self.cfg.budgets[k % len(self.cfg.budgets)]
        req = self.router.submit([1], budget)
        self.submitted.append(req)
        self.n_submitted += 1
        if req.state == _QUEUED:
            self.accepted.add(req.rid)

    def _ev_admit(self, r: int) -> None:
        self._require(r not in self.lost, f"replica {r} lost")
        s = self._sched(r)
        free_before = list(s.free_slots)
        want = [q.rid for q in list(s.queue)[: len(free_before)]]
        got = s.admit()
        if [q.rid for q in got] != want:
            raise ProtocolError(
                "fifo",
                f"admit on replica {r} took {[q.rid for q in got]}, "
                f"FIFO head order is {want}",
            )
        if [q.slot for q in got] != free_before[: len(got)]:
            raise ProtocolError(
                "fifo",
                f"admit on replica {r} assigned slots "
                f"{[q.slot for q in got]}, deterministic order is "
                f"{free_before[: len(got)]}",
            )

    def _ev_token(self, r: int, slot: int) -> None:
        self._require(r not in self.lost, f"replica {r} lost")
        s = self._sched(r)
        self._require(slot < s.num_slots, "no such slot")
        tok = 1 if self.cfg.eos_id != 1 else 2
        s.record_token(slot, tok)

    def _ev_eos(self, r: int, slot: int) -> None:
        self._require(r not in self.lost, f"replica {r} lost")
        s = self._sched(r)
        self._require(slot < s.num_slots, "no such slot")
        s.record_token(slot, self.cfg.eos_id)

    def _ev_evict(self, k: int, r: int) -> None:
        self._require(k < self.n_submitted, "no such submission")
        req = self.submitted[k]
        self._require(
            req.rid in self._sched(r).requests,
            f"replica {r} does not know rid {req.rid}",
        )
        self._sched(r).evict(req.rid)

    def _ev_degrade(self, r: int) -> None:
        self._require(r not in self.lost, f"replica {r} lost")
        before = self._queue_snapshot()
        self.router.observe_step(r, self._next_step(), _STRAGGLE_DT)
        if self.router.health[r].healthy:
            raise ProtocolError(
                "hysteresis",
                f"straggler signal on warmed replica {r} did not degrade it",
            )
        self._check_no_reorder(before)

    def _ev_recover(self, r: int) -> None:
        self._require(r not in self.lost, f"replica {r} lost")
        h = self.router.health[r]
        pre_healthy, pre_clean = h.healthy, h._clean
        self.router.observe_step(r, self._next_step(), _CLEAN_DT)
        if not pre_healthy:
            want = pre_clean + 1 >= h.recovery
            if h.healthy != want:
                raise ProtocolError(
                    "hysteresis",
                    f"replica {r}: {pre_clean + 1} consecutive clean steps "
                    f"with recovery={h.recovery} -> healthy={h.healthy}, "
                    f"expected {want}",
                )

    def _ev_reroute(self, r: int) -> None:
        self._require(r not in self.lost, f"replica {r} lost")
        before = self._queue_snapshot()
        self.router.reroute(r)
        self._check_no_reorder(before)

    def _ev_loss(self, r: int) -> None:
        self._require(r not in self.lost, f"replica {r} already lost")
        self._require(len(self._alive()) >= 2, "cannot lose the last replica")
        before = self._queue_snapshot()
        self.lost.add(r)
        self.router.fail_replica(r)
        s = self._sched(r)
        if s.queue or any(q is not None for q in s.slots):
            raise ProtocolError(
                "liveness",
                f"failed replica {r} still holds requests after the "
                f"re-plan: queue={[q.rid for q in s.queue]}, "
                f"slots={[q.rid if q else None for q in s.slots]}",
            )
        self._check_no_reorder(before)

    # -- FIFO-order postconditions -----------------------------------------

    def _queue_snapshot(self) -> dict[int, list[int]]:
        return {
            r: [q.rid for q in self._sched(r).queue]
            for r in range(self.cfg.replicas)
        }

    def _check_no_reorder(self, before: dict[int, list[int]]) -> None:
        """No drain/reroute may reorder co-resident requests: any two
        rids that shared a queue before and share a queue after must
        keep their relative order, and survivors of a queue must form
        a contiguous prefix (movers are appended at the tail)."""
        after = self._queue_snapshot()
        for i, old in before.items():
            pos = {rid: p for p, rid in enumerate(old)}
            for j, new in after.items():
                shared = [rid for rid in new if rid in pos]
                order = [pos[rid] for rid in shared]
                if order != sorted(order):
                    raise ProtocolError(
                        "fifo",
                        f"queue {i}->{j} reordered rids {shared} "
                        f"(old positions {order})",
                    )
        for j, new in after.items():
            old_members = set(before[j])
            kept = [rid for rid in new if rid in old_members]
            if new[: len(kept)] != kept:
                raise ProtocolError(
                    "fifo",
                    f"queue {j}: rerouted requests were not appended at "
                    f"the tail (old {before[j]}, new {new})",
                )

    # -- canonical state ----------------------------------------------------

    def canonical(self) -> tuple:
        """Canonical hashable state: live rids renumbered in structural
        scan order (symmetry reduction), terminal requests collapsed to
        per-class counts, monotone telemetry dropped."""
        idx: dict[int, int] = {}

        def live(req):
            return (
                idx.setdefault(req.rid, len(idx)),
                req.remaining,
            )

        reps = []
        for i, rep in enumerate(self.router.replicas):
            s = rep.scheduler
            h = self.router.health[i]
            m = h.monitor
            reps.append((
                i in self.lost,
                h.healthy,
                h._clean,
                None if m.ewma is None else round(m.ewma, 9),
                min(m.count, m.warmup + 1),
                tuple(live(q) for q in s.queue),
                tuple(None if q is None else live(q) for q in s.slots),
                tuple(s._free),
            ))
        owners = []
        for k, req in enumerate(self.submitted):
            if req.done or req.rid not in idx:
                continue
            owned_by = tuple(
                r
                for r in range(self.cfg.replicas)
                if req.rid in self._sched(r).requests
            )
            owners.append((idx[req.rid], owned_by))
        term = Counter(req.state for req in self.submitted if req.done)
        limbo = tuple(
            (req.state, req.remaining)
            for req in self.submitted
            if not req.done and req.rid not in idx
        )
        return (
            tuple(reps),
            tuple(sorted(owners)),
            self.cfg.requests - self.n_submitted,
            term[_FINISHED],
            term[_EVICTED],
            term[_REJECTED],
            limbo,
        )

    def all_idle(self) -> bool:
        return all(
            not s.queue and not any(q is not None for q in s.slots)
            for s in (self._sched(r) for r in range(self.cfg.replicas))
        )


# ---------------------------------------------------------------------------
# safety rules (checked at every reachable state)
# ---------------------------------------------------------------------------


def _safety_violations(w: World) -> list[Violation]:
    out: list[Violation] = []

    def bad(rule, detail):
        out.append(Violation(rule, detail, w.trace, w.cfg))

    scheds = [w._sched(r) for r in range(w.cfg.replicas)]

    # structural slot accounting, per replica (the scheduler's own hook)
    for i, s in enumerate(scheds):
        try:
            s.check_invariants()
        except AssertionError as e:
            bad("slot-accounting", f"replica {i}: check_invariants: {e}")

    # conservation: each submitted rid in exactly the container its
    # state names, across ALL replicas
    holder: dict[int, list[tuple[int, str]]] = {}
    for i, s in enumerate(scheds):
        for pos, req in enumerate(s.queue):
            holder.setdefault(req.rid, []).append((i, f"queue[{pos}]"))
        for slot, req in enumerate(s.slots):
            if req is not None:
                holder.setdefault(req.rid, []).append((i, f"slot[{slot}]"))
    for k, req in enumerate(w.submitted):
        where = holder.pop(req.rid, [])
        if req.state == _QUEUED:
            if len(where) != 1 or "queue" not in where[0][1]:
                bad(
                    "conservation",
                    f"submission {k} (rid {req.rid}) is QUEUED but held "
                    f"by {where}",
                )
        elif req.state == _ACTIVE:
            if len(where) != 1 or "slot" not in where[0][1]:
                bad(
                    "conservation",
                    f"submission {k} (rid {req.rid}) is ACTIVE but held "
                    f"by {where}",
                )
        elif req.done:
            if where:
                bad(
                    "conservation",
                    f"submission {k} (rid {req.rid}) is terminal "
                    f"({req.state}) but still held by {where}",
                )
            frozen = w.frozen.setdefault(req.rid, len(req.generated))
            if (
                len(req.generated) != frozen
                or req.slot is not None
                or req.remaining != 0
            ):
                bad(
                    "silence",
                    f"terminal submission {k} (rid {req.rid}, "
                    f"{req.state}) changed after the end: "
                    f"generated {frozen}->{len(req.generated)}, "
                    f"slot={req.slot}, remaining={req.remaining}",
                )
        else:
            bad("conservation", f"rid {req.rid} in unknown state {req.state}")
        if req.rid in w.accepted and req.state == _REJECTED:
            bad(
                "acceptance",
                f"submission {k} (rid {req.rid}) was accepted (QUEUED) "
                f"but later REJECTED — backpressure must happen at "
                f"submit, not mid-flight",
            )
        if req.state in (_QUEUED, _ACTIVE):
            p = w.router.placement.get(req.rid)
            actual = where[0][0] if len(where) == 1 else None
            if p is None or (actual is not None and p != actual):
                bad(
                    "placement",
                    f"rid {req.rid} is {req.state} on replica {actual} "
                    f"but router.placement says {p}",
                )
    for rid, where in holder.items():
        bad("conservation", f"unsubmitted rid {rid} held by {where}")

    # ownership: a live rid is registered with exactly one replica —
    # a stale second registry entry lets evict race a reroute
    own = Counter()
    for i, s in enumerate(scheds):
        for rid, req in s.requests.items():
            if not req.done:
                own[rid] += 1
    for rid, n in own.items():
        if n > 1:
            bad(
                "ownership",
                f"live rid {rid} is registered with {n} replicas — "
                f"evicting through the stale owner corrupts or crashes",
            )
    return out


# ---------------------------------------------------------------------------
# quiescence-style liveness
# ---------------------------------------------------------------------------


def quiesce(world: World, *, limit: int | None = None) -> Violation | None:
    """From ``world``, stop admissions and drive drain events (recover,
    admit, decode tokens) on every surviving replica; the system must
    reach ``idle`` within a budget-derived bound.  Returns a liveness
    :class:`Violation` (with the *reaching* trace) if it does not."""
    cfg = world.cfg
    if limit is None:
        limit = (
            cfg.requests * max(cfg.budgets)
            + cfg.replicas * (cfg.recovery + 2)
            + cfg.requests
            + cfg.replicas * cfg.slots
            + 8
        )
    w = world.clone()
    for _ in range(limit):
        if w.all_idle():
            return None
        for r in range(cfg.replicas):
            if r in w.lost:
                continue
            h = w.router.health[r]
            try:
                if not h.healthy:
                    v = w.apply_checked(("recover", r))
                    if v is not None:
                        return _as_liveness(v, world)
                s = w._sched(r)
                if s.queue and s.free_slots:
                    v = w.apply_checked(("admit", r))
                    if v is not None:
                        return _as_liveness(v, world)
                for slot, req in enumerate(s.slots):
                    if req is not None:
                        v = w.apply_checked(("token", r, slot))
                        if v is not None:
                            return _as_liveness(v, world)
            except TraceNotApplicable:
                continue
    stuck = {
        r: {
            "queue": [q.rid for q in w._sched(r).queue],
            "slots": [
                q.rid if q is not None else None for q in w._sched(r).slots
            ],
            "lost": r in w.lost,
            "healthy": w.router.health[r].healthy,
        }
        for r in range(cfg.replicas)
        if w._sched(r).queue
        or any(q is not None for q in w._sched(r).slots)
    }
    return Violation(
        "liveness",
        f"state does not quiesce: after {limit} drain rounds requests "
        f"remain stranded: {stuck}",
        world.trace,
        cfg,
    )


def _as_liveness(v: Violation, world: World) -> Violation:
    return Violation(
        "liveness",
        f"drain from this state hits a violation: [{v.rule}] {v.detail}",
        world.trace,
        world.cfg,
    )


# ---------------------------------------------------------------------------
# exploration driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CheckReport:
    """Result of one exhaustive exploration."""

    config: CheckConfig
    states: int            #: distinct canonical states reached
    transitions: int       #: events applied (pre-dedup)
    depth: int             #: deepest fully-expanded BFS level
    complete: bool         #: frontier emptied (full closure) vs depth cap
    violations: list[Violation]
    occupancies: tuple[int, ...]  #: reachable per-replica active-slot counts
    seconds: float

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def dedup_ratio(self) -> float:
        return self.transitions / max(1, self.states)

    def to_row(self) -> dict:
        return {
            "config": dataclasses.asdict(self.config),
            "states": self.states,
            "transitions": self.transitions,
            "dedup_ratio": round(self.dedup_ratio, 3),
            "depth": self.depth,
            "complete": self.complete,
            "violations": [v.to_row() for v in self.violations],
            "occupancies": list(self.occupancies),
            "seconds": round(self.seconds, 3),
        }


def check_protocol(
    cfg: CheckConfig,
    *,
    scheduler_cls=None,
    router_cls=None,
    health_cls=None,
    max_violations: int = 1,
    shrink: bool = True,
) -> CheckReport:
    """Breadth-first exhaustive exploration of every event interleaving
    up to ``cfg.depth`` (or full closure), deduped by canonical state.
    Stops at ``max_violations`` counterexamples; each is shrunk to a
    locally-minimal replayable trace."""
    import logging

    t0 = time.perf_counter()
    classes = dict(
        scheduler_cls=scheduler_cls,
        router_cls=router_cls,
        health_cls=health_cls,
    )
    # thousands of deliberate straggler injections: mute the runtime's
    # per-event warning for the duration of the exploration
    runtime_log = logging.getLogger("repro.runtime")
    prior_level = runtime_log.level
    runtime_log.setLevel(logging.ERROR)
    try:
        return _explore(cfg, classes, max_violations, shrink, t0)
    finally:
        runtime_log.setLevel(prior_level)


def _explore(cfg, classes, max_violations, shrink, t0) -> "CheckReport":
    root = World(cfg, **classes)
    violations: list[Violation] = []
    seen = {root.canonical()}
    occupancies: set[int] = set()

    def note_occupancy(w: World) -> None:
        for r in range(cfg.replicas):
            occupancies.add(sum(w._sched(r).active_mask()))

    note_occupancy(root)
    sv = _safety_violations(root)
    if not sv and cfg.liveness:
        lv = quiesce(root)
        if lv is not None:
            sv = [lv]
    violations.extend(sv)

    frontier = [root]
    depth = 0
    transitions = 0
    complete = True
    while frontier and len(violations) < max_violations:
        if cfg.depth is not None and depth >= cfg.depth:
            complete = False
            break
        nxt: list[World] = []
        for w in frontier:
            for ev in w.enabled_events():
                child = w.clone()
                transitions += 1
                v = child.apply_checked(ev)
                if v is None:
                    sv = _safety_violations(child)
                    v = sv[0] if sv else None
                if v is None:
                    # dedup before the liveness drain: quiescence is a
                    # function of the canonical state (queues, slots,
                    # health, losses fully determine drain behavior),
                    # so one check per distinct state is exhaustive
                    key = child.canonical()
                    if key in seen:
                        continue
                    seen.add(key)
                    if cfg.liveness:
                        v = quiesce(child)
                if v is not None:
                    violations.append(v)
                    if len(violations) >= max_violations:
                        break
                    continue
                note_occupancy(child)
                nxt.append(child)
            if len(violations) >= max_violations:
                break
        if len(violations) >= max_violations:
            complete = False
            break
        depth += 1
        frontier = nxt

    if shrink:
        violations = [
            dataclasses.replace(
                v, trace=shrink_trace(cfg, v.trace, v.rule, **classes)
            )
            for v in violations
        ]
    return CheckReport(
        config=cfg,
        states=len(seen),
        transitions=transitions,
        depth=depth,
        complete=complete,
        violations=violations,
        occupancies=tuple(sorted(occupancies)),
        seconds=time.perf_counter() - t0,
    )


# ---------------------------------------------------------------------------
# replay, shrinking, regression-test helpers
# ---------------------------------------------------------------------------


def run_trace(
    cfg: CheckConfig,
    trace,
    *,
    scheduler_cls=None,
    router_cls=None,
    health_cls=None,
) -> list[Violation]:
    """Replay an event trace on a fresh world; returns the violations
    it produces (stopping at the first).  Raises
    :class:`TraceNotApplicable` if an event is not enabled — traces are
    deterministic, so a recorded counterexample always replays."""
    import logging

    logging.getLogger("repro.runtime").setLevel(logging.ERROR)
    w = World(
        cfg,
        scheduler_cls=scheduler_cls,
        router_cls=router_cls,
        health_cls=health_cls,
    )
    for ev in trace:
        v = w.apply_checked(tuple(ev))
        if v is None:
            sv = _safety_violations(w)
            v = sv[0] if sv else None
        if v is not None:
            return [v]
    if cfg.liveness:
        v = quiesce(w)
        if v is not None:
            return [v]
    return []


def shrink_trace(cfg: CheckConfig, trace, rule: str, **classes) -> tuple:
    """Greedy delta-debugging: drop events while the trace still
    violates ``rule``.  BFS already gives minimal *depth*; this removes
    incidental events, yielding a locally-minimal witness."""

    def violates(tr) -> bool:
        try:
            return any(v.rule == rule for v in run_trace(cfg, tr, **classes))
        except TraceNotApplicable:
            return False

    trace = tuple(tuple(e) for e in trace)
    if not violates(trace):  # e.g. liveness found mid-drain; keep as-is
        return trace
    changed = True
    while changed:
        changed = False
        for i in reversed(range(len(trace))):
            cand = trace[:i] + trace[i + 1:]
            if violates(cand):
                trace = cand
                changed = True
                break
    return trace


def assert_trace_violates(cfg: CheckConfig, trace, rule: str, **classes):
    """Regression-test hook: the trace must reproduce ``rule``."""
    vs = run_trace(cfg, trace, **classes)
    assert any(v.rule == rule for v in vs), (
        f"expected a {rule!r} violation, got "
        f"{[(v.rule, v.detail) for v in vs]}"
    )
    return vs


def assert_trace_clean(cfg: CheckConfig, trace, **classes) -> None:
    """Regression-test hook: the (formerly violating) trace must now
    replay without any violation."""
    vs = run_trace(cfg, trace, **classes)
    assert not vs, f"trace not clean: {[(v.rule, v.detail) for v in vs]}"


# ---------------------------------------------------------------------------
# layer-0 <-> layer-2 link
# ---------------------------------------------------------------------------


def verify_decode_geometry_link(num_slots: int, group: int) -> dict:
    """Prove the checker's admissible decode-step states are exactly
    the slot geometries the linted decode slice is swept over.

    A tiny occupancy closure drives a **real** :class:`Scheduler`
    through submit/admit/token/evict and collects every reachable
    active-slot count; the ragged per-chip split of ``num_slots`` over
    ``group`` chips (``Scheduler.shard_geometry`` ==
    ``napalg.ragged_splits``) must then be exactly the padded hull of
    those occupancies — ``b_max = max(geometry)`` rows per chip, the
    shape ``python -m repro.analysis --spmd`` lints the decode slice
    at.  Raises ``AssertionError`` if the link is broken."""
    from repro.core import napalg
    from repro.serve.scheduler import Scheduler

    probe = Scheduler(num_slots)
    geometry = probe.shard_geometry(group)
    assert geometry == napalg.ragged_splits(num_slots, group), (
        geometry, num_slots, group,
    )

    # occupancy closure: canonical = (submits left, queued, per-slot mask)
    def mk():
        return Scheduler(num_slots)

    max_requests = num_slots + 1
    reachable: set[int] = set()
    seen: set[tuple] = set()

    def canon(s, n_sub):
        return (
            max_requests - n_sub,
            len(s.queue),
            tuple(s.active_mask()),
        )

    frontier = [(mk(), 0)]
    seen.add(canon(*frontier[0]))
    while frontier:
        nxt = []
        for s, n_sub in frontier:
            reachable.add(sum(s.active_mask()))
            children = []
            if n_sub < max_requests:
                c = _clone_plain_scheduler(s)
                c.submit([1], 1)
                children.append((c, n_sub + 1))
            if s.queue and s.free_slots:
                c = _clone_plain_scheduler(s)
                c.admit()
                children.append((c, n_sub))
            for slot, req in enumerate(s.slots):
                if req is not None:
                    c = _clone_plain_scheduler(s)
                    c.record_token(slot, 1)  # budget 1: token == finish
                    children.append((c, n_sub))
                    c2 = _clone_plain_scheduler(s)
                    c2.evict(c2.slots[slot].rid)
                    children.append((c2, n_sub))
            for c, n in children:
                key = canon(c, n)
                if key not in seen:
                    seen.add(key)
                    nxt.append((c, n))
        frontier = nxt

    assert reachable == set(range(num_slots + 1)), reachable
    b_max = max(geometry)
    # padded hull: the deepest per-chip row any admissible occupancy
    # needs equals the b_max the lint swept the decode slice at
    need = 0
    for occ in reachable:
        rows, left = 0, occ
        for g in geometry:
            rows = max(rows, min(g, left))
            left -= min(g, left)
        need = max(need, rows)
    assert need == b_max, (need, b_max, geometry)
    return {
        "num_slots": num_slots,
        "group": group,
        "geometry": list(geometry),
        "admissible_occupancies": sorted(reachable),
        "b_max": b_max,
        "occupancy_states": len(seen),
        "ok": True,
    }


def _clone_plain_scheduler(s):
    from collections import deque

    memo: dict = {}
    c = type(s).__new__(type(s))
    c.num_slots, c.max_queue = s.num_slots, s.max_queue
    c.buckets, c.eos_id = s.buckets, s.eos_id
    c.queue = deque(_clone_request(r, memo) for r in s.queue)
    c.slots = [None if r is None else _clone_request(r, memo) for r in s.slots]
    c._free = list(s._free)
    c._ids = s._ids
    c.requests = {rid: _clone_request(r, memo) for rid, r in s.requests.items()}
    c.n_rejected = s.n_rejected
    return c
