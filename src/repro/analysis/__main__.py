"""Grid-sweep verification driver: ``python -m repro.analysis``.

Runs both static-analysis passes and emits the ``BENCH_7.json``
verification table:

1. **Schedule sweep** — every registered engine x the grid matrix
   (degenerate ``n=1``/``ppn=1`` grids, prime node counts, ragged
   payloads, chunk depths) through the four schedule-verifier passes.
   Engines without a schedule builder (the native psum fallbacks) are
   reported as ``native`` rows — a single native collective has no
   message schedule to verify.
2. **HLO wire-lint** — compiles the compressed fused-bucket gradient
   sync on 8 virtual CPU devices and runs the wire-dtype,
   collective-count and stable-lowering rules over the jaxpr and the
   optimized HLO.

3. **SPMD jaxpr lint** (``--spmd``, the ``BENCH_8.json`` gate) — the
   middle layer of the proof chain: every registered engine's *executed*
   lowering is traced to a jaxpr and checked for collective uniformity,
   axis discipline, numerics flow and schedule-vs-jaxpr byte equality
   (:func:`repro.core.comm.lint_lowering`), then the same rules run
   over the compressed grad-sync step, the data-parallel train step and
   the serve decode loop.

4. **Protocol model check** (``--protocol``, the ``BENCH_10.json``
   gate) — layer 0 of the proof chain: exhaustive explicit-state
   exploration of the serving control plane (scheduler + router +
   replica health, the real objects) over the small-scope grid in
   :data:`PROTOCOL_GRID`; fails on any safety/liveness violation *or*
   on state-space coverage regressing below the recorded floor, and
   re-asserts the layer-0 ↔ layer-2 link (admissible slot occupancies
   == the ragged geometry the decode slice is linted at).

Exits non-zero on any violation, so CI can gate on it::

    PYTHONPATH=src python -m repro.analysis --json reports/BENCH_7.json
    PYTHONPATH=src python -m repro.analysis --spmd --json reports/BENCH_8.json
    PYTHONPATH=src python -m repro.analysis --protocol --json reports/BENCH_10.json

``--skip-hlo`` runs only the (fast, jax-free) schedule sweep;
``--skip-schedules`` only the lint.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# the HLO pass compiles on virtual CPU devices: the flag must be set
# before anything imports jax, which is why this module (and the whole
# analysis package) keeps jax out of module scope
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def run_schedule_sweep() -> dict:
    from repro.core import comm

    from . import schedule_verifier as sv

    rows = []
    per_engine: dict[str, dict] = {}
    for key in sorted(comm.registered_engines()):
        collective, name = key.split(":", 1)
        spec = comm.get_engine(name, collective)
        reports = sv.verify_spec_grid(spec)
        n_bad = sum(1 for r in reports if not r.ok)
        n_native = sum(
            1 for r in reports if any(n.startswith("native") for n in r.notes)
        )
        n_skipped = sum(
            1 for r in reports if any(n.startswith("skipped") for n in r.notes)
        )
        per_engine[key] = {
            "cells": len(reports),
            "verified": len(reports) - n_bad - n_native - n_skipped,
            "native": n_native,
            "skipped_below_min_grid": n_skipped,
            "violations": n_bad,
        }
        rows.extend(r.to_row() for r in reports)
        status = "FAIL" if n_bad else "ok"
        print(
            f"  {key:28s} {per_engine[key]['verified']:4d} verified "
            f"{n_native:4d} native {n_skipped:4d} skipped "
            f"{n_bad:3d} violations  {status}"
        )
    n_violations = sum(e["violations"] for e in per_engine.values())
    return {
        "grid_matrix": [list(g) for g in sv.GRID_MATRIX],
        "payload_elems": list(sv.PAYLOAD_ELEMS),
        "engines": per_engine,
        "cells": len(rows),
        "violations": n_violations,
        "rows": rows,
    }


def run_hlo_lint() -> dict:
    """Compile the compressed fused-bucket grad sync and lint its wire."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core import comm, grad_sync
    from repro.launch.mesh import make_mesh

    from . import hlo_lint

    mesh = make_mesh((2, 4), ("pod", "data"))
    shapes = [(64 + 32 * i,) for i in range(3)]
    payload_elems = sum(s[0] for s in shapes)

    def compiled(bits):
        policy = comm.CommPolicy(
            algorithm="nap", mean=True, compress_bits=bits
        )

        def f(*leaves):
            topo = comm.Topology.from_mesh(mesh)
            ctx = comm.CommContext(topo, policy)
            plan = grad_sync.plan_for_tree(
                [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes],
                cfg=policy, topology=topo,
            )
            out = grad_sync.sync_with_context(list(leaves), ctx, plan=plan)
            return jnp.concatenate(out)

        args = [jnp.zeros(s, jnp.float32) for s in shapes]
        g = compat.shard_map(
            f, mesh=mesh,
            in_specs=tuple(P() for _ in args),
            out_specs=P(),
            check_vma=False,
        )
        return g, args

    rows = []

    def record(context: str, violations) -> None:
        for v in violations:
            rows.append({"context": context, **v.to_row()})
        status = "FAIL" if violations else "ok"
        print(f"  {context:42s} {len(violations):2d} violations  {status}")

    for bits in (8, 4):
        g, args = compiled(bits)
        jaxpr = str(jax.make_jaxpr(g)(*args))
        record(
            f"jaxpr[bits={bits}] pallas_call budget",
            hlo_lint.lint_collective_counts(jaxpr, {"pallas_call": 4}),
        )
        hlo = jax.jit(g).lower(*args).compile().as_text()
        record(
            f"hlo[bits={bits}] compressed wire",
            hlo_lint.lint_compressed_wire(
                hlo, bits=bits, payload_elems=payload_elems, ppn=4
            ),
        )
        record(
            f"hlo[bits={bits}] replica-group partition",
            hlo_lint.lint_replica_groups(
                hlo, num_devices=len(mesh.devices.flat)
            ),
        )
    g, args = compiled(8)
    record(
        "stable lowering (no silent recompile)",
        hlo_lint.lint_stable_lowering(g, *args),
    )
    return {"rows": rows, "violations": len(rows)}


#: engine-cell matrix of the --spmd sweep: grids past the engines'
#: minimums plus one asymmetric shape, at full and half wire precision
_SPMD_GRIDS = ((2, 2), (3, 2), (2, 4))
_SPMD_DTYPES = ("float32", "bfloat16")


def run_spmd_sweep() -> dict:
    """Trace-and-lint sweep over every engine lowering + the compiled
    workloads (grad sync, train step, serve decode)."""
    import jax
    import jax.numpy as jnp

    from repro.core import comm, grad_sync

    from . import spmd_lint

    rows = []
    n_violations = 0

    def record(rep) -> None:
        nonlocal n_violations
        rows.append(rep.to_row())
        n_violations += len(rep.violations)
        status = "FAIL" if rep.violations else "ok"
        byte_col = (
            "bytes=?"
            if rep.internode_bytes_per_chip is None
            else f"bytes={rep.internode_bytes_per_chip:g}"
            + ("" if rep.declared_bytes is None else "=declared")
        )
        print(
            f"  {rep.label:40s} {rep.collectives:3d} collectives "
            f"{byte_col:18s} {len(rep.violations):2d} violations  {status}"
        )

    # -- 1. every registered engine's executed lowering ------------------
    per_engine: dict[str, dict] = {}
    byte_verified = 0
    for key in sorted(comm.registered_engines()):
        collective, name = key.split(":", 1)
        spec = comm.get_engine(name, collective)
        cells = skipped = bounded = bad = 0
        for n, p in _SPMD_GRIDS:
            if n < spec.min_nodes or p < spec.min_ppn:
                skipped += len(_SPMD_DTYPES)
                continue
            for dt in _SPMD_DTYPES:
                rep = comm.lint_lowering(
                    name, n_nodes=n, ppn=p, dtype=dt,
                    raise_on_violation=False,
                )
                record(rep)
                cells += 1
                if rep.declared_bytes is not None:
                    bounded += 1
                    byte_verified += 1
                if not rep.ok:
                    bad += 1
        per_engine[key] = {
            "cells": cells,
            "byte_verified": bounded,
            "skipped_below_min_grid": skipped,
            "violations": bad,
        }

    # -- 2. the compressed grad-sync step (shard-level trace) ------------
    topo = comm.Topology(2, 4, inter_axes=("pod",), intra_axes=("data",))
    axis_env = [("pod", 2), ("data", 4)]
    axis_sizes = dict(axis_env)
    shapes = [(64 + 32 * i,) for i in range(3)]
    leaves = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    for bits in (8, 4):
        policy = comm.CommPolicy(
            algorithm="nap", mean=True, compress_bits=bits
        )
        ctx = comm.CommContext(topo, policy)
        plan = grad_sync.plan_for_tree(leaves, cfg=policy, topology=topo)

        def f(*ls):
            return jnp.concatenate(
                grad_sync.sync_with_context(list(ls), ctx, plan=plan)
            )

        closed = jax.make_jaxpr(f, axis_env=axis_env)(*leaves)
        record(
            spmd_lint.lint_jaxpr(
                closed, axis_sizes=axis_sizes,
                inter_axes=("pod",), intra_axes=("data",),
                label=f"grad_sync[bits={bits}]",
            )
        )

    # -- 3. the data-parallel train step (launch/steps) ------------------
    import dataclasses as _dc

    from repro.configs import ARCHS, reduced
    from repro.configs.base import OptimizerConfig
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import make_dp_train_step
    from repro.models import build_model
    from repro.optim import adamw_init

    mesh = make_mesh((2, 4), ("pod", "data"))
    cfg = _dc.replace(reduced(ARCHS["minicpm-2b"]), dtype="float32")
    opt_cfg = OptimizerConfig(lr=1e-2, schedule="constant", warmup_steps=1)
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    state_sds = {
        "params": params_sds,
        "opt": jax.eval_shape(adamw_init, params_sds),
    }
    batch_sds = {"tokens": jax.ShapeDtypeStruct((16, 32), jnp.int32)}
    step = make_dp_train_step(
        cfg, opt_cfg, mesh, grad_sync.GradSyncConfig(
            algorithm="nap", mean=True,
        ),
    )
    closed = jax.make_jaxpr(step)(state_sds, batch_sds)
    record(
        spmd_lint.lint_jaxpr(
            closed, axis_sizes=axis_sizes,
            inter_axes=("pod",), intra_axes=("data",),
            label="train_step[nap]",
            # mesh-level program: the step's own shard_map binds the
            # axes; inputs are host values, uniform until sharded
            axes_bound_at_root=False,
        )
    )

    # -- 4. the serve decode loop (launch/serve) -------------------------
    from repro.launch import serve as serve_mod

    serve_model = build_model(cfg)
    shard_fn = serve_mod.make_serve_shard(
        serve_model, comm.CommContext(topo), gen_len=4, max_len=10,
        eos_id=1,
    )
    prompts_sds = jax.ShapeDtypeStruct((1, 6), jnp.int32)
    closed = jax.make_jaxpr(shard_fn, axis_env=axis_env)(
        params_sds, prompts_sds
    )
    record(
        spmd_lint.lint_jaxpr(
            closed, axis_sizes=axis_sizes,
            inter_axes=("pod",), intra_axes=("data",),
            label="serve_decode[eos early-exit]",
        )
    )

    # -- 5. the serving spine's scheduler-driven decode slice ------------
    # (repro.serve): slot-stacked continuous-batching decode with the
    # tensor-parallel logits head — allgather + latency-regime allreduce
    # + psum-min early exit, the full decode-collective set per token
    import functools

    from repro.serve import decode as serve_decode

    from .protocol_check import verify_decode_geometry_link

    # layer-0 <-> layer-2 link: the batch width this slice is linted at
    # comes from the protocol checker's admissible-occupancy closure
    # over a real Scheduler — the linted shape IS the proved geometry
    link = verify_decode_geometry_link(8, topo.group)
    b_max = link["b_max"]  # max of the ragged_splits slot geometry
    b1_cache_sds = jax.eval_shape(
        functools.partial(serve_model.init_decode, batch_size=1, max_len=10),
        params_sds,
    )
    cache_sds = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((b_max,) + s.shape, s.dtype),
        b1_cache_sds,
    )
    tok_sds = jax.ShapeDtypeStruct((b_max, 1), jnp.int32)
    active_sds = jax.ShapeDtypeStruct((b_max,), jnp.bool_)
    slice_fn = serve_decode.make_decode_slice(
        serve_model, comm.CommContext(topo), slice_len=4, eos_id=1
    )
    closed = jax.make_jaxpr(slice_fn, axis_env=axis_env)(
        params_sds, cache_sds, tok_sds, active_sds
    )
    record(
        spmd_lint.lint_jaxpr(
            closed, axis_sizes=axis_sizes,
            inter_axes=("pod",), intra_axes=("data",),
            label="serve_engine[continuous batching]",
        )
    )

    return {
        "grids": [list(g) for g in _SPMD_GRIDS],
        "dtypes": list(_SPMD_DTYPES),
        "engines": per_engine,
        "byte_verified_cells": byte_verified,
        "cells": len(rows),
        "violations": n_violations,
        "rows": rows,
    }


#: the --protocol small-scope grid: (config, recorded state floor).
#: Exploration is deterministic, so the floors are the exact counts at
#: the time of recording; CI fails if coverage ever regresses below
#: them (a canonicalization or event-alphabet change silently shrinking
#: the explored space would otherwise look like a pass).
def _protocol_grid():
    from .protocol_check import CheckConfig

    return (
        # pure scheduler protocol, single replica, full closure
        (CheckConfig(replicas=1, slots=2, queue=2, requests=4,
                     budgets=(2, 1), faults=False, losses=False,
                     depth=None), 230),
        # two replicas with the full fault alphabet, full closure
        (CheckConfig(replicas=2, slots=1, queue=1, requests=3,
                     budgets=(2, 1), recovery=2, depth=None), 3591),
        # three replicas: reroute fan-out + double loss, bounded depth
        (CheckConfig(replicas=3, slots=1, queue=1, requests=4,
                     budgets=(1,), recovery=2, depth=8), 9890),
        # the acceptance scope: 2 replicas x 3 slots x 5 requests to
        # event depth 12 (the ISSUE-10 floor), full fault alphabet
        (CheckConfig(replicas=2, slots=3, queue=2, requests=5,
                     budgets=(2, 1), recovery=2, depth=12), 77796),
    )


def run_protocol_sweep() -> dict:
    """Exhaustive layer-0 sweep over the small-scope protocol grid."""
    from . import protocol_check as pc

    rows = []
    n_violations = 0
    coverage_failures = 0
    for cfg, floor in _protocol_grid():
        rep = pc.check_protocol(cfg)
        row = rep.to_row()
        row["min_states"] = floor
        row["coverage_ok"] = rep.states >= floor
        rows.append(row)
        n_violations += len(rep.violations)
        if not row["coverage_ok"]:
            coverage_failures += 1
        status = (
            "FAIL"
            if rep.violations or not row["coverage_ok"]
            else "ok"
        )
        scope = (
            f"r{cfg.replicas} s{cfg.slots} q{cfg.queue} "
            f"n{cfg.requests} d{cfg.depth or 'closure'}"
        )
        print(
            f"  {scope:28s} {rep.states:7d} states "
            f"(floor {floor:7d}) {rep.transitions:8d} transitions "
            f"dedup {rep.dedup_ratio:5.2f}x depth {rep.depth:2d} "
            f"{len(rep.violations):2d} violations  {status}"
        )
        for v in rep.violations:
            print(f"    !! [{v.rule}] {v.detail}")
            print(f"       trace: {list(v.trace)}")

    # layer-0 <-> layer-2 link: the occupancies the protocol admits are
    # exactly the ragged slot geometry the --spmd sweep lints the
    # decode slice at (same Scheduler.shard_geometry call, both sides)
    from repro.core import comm

    topo = comm.Topology(2, 4, inter_axes=("pod",), intra_axes=("data",))
    link = pc.verify_decode_geometry_link(8, topo.group)
    print(
        f"  layer-2 link: occupancies 0..{max(link['admissible_occupancies'])}"
        f" on geometry {link['geometry']} -> b_max={link['b_max']}  ok"
    )
    return {
        "rows": rows,
        "layer2_link": link,
        "coverage_failures": coverage_failures,
        "states_total": sum(r["states"] for r in rows),
        "transitions_total": sum(r["transitions"] for r in rows),
        # the CI gate: protocol violations AND coverage regressions
        # both fail the run
        "violations": n_violations + coverage_failures,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__
    )
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the verification table here "
                         "(BENCH_7, or BENCH_8 with --spmd)")
    ap.add_argument("--spmd", action="store_true",
                    help="run the SPMD jaxpr lint sweep (BENCH_8) "
                         "instead of the BENCH_7 passes")
    ap.add_argument("--protocol", action="store_true",
                    help="run the layer-0 protocol model check over "
                         "the small-scope grid (BENCH_10)")
    ap.add_argument("--skip-hlo", action="store_true",
                    help="schedule sweep only (fast, jax-free)")
    ap.add_argument("--skip-schedules", action="store_true",
                    help="HLO lint only")
    args = ap.parse_args(argv)

    if args.protocol:
        report = {"bench": "BENCH_10", "ok": True}
        print("protocol model check (layer 0):")
        report["protocol"] = run_protocol_sweep()
    elif args.spmd:
        report = {"bench": "BENCH_8", "ok": True}
        print("SPMD jaxpr lint sweep:")
        report["spmd_lint"] = run_spmd_sweep()
    else:
        report = {"bench": "BENCH_7", "ok": True}
        if not args.skip_schedules:
            print("schedule verification sweep:")
            report["schedule_verification"] = run_schedule_sweep()
        if not args.skip_hlo:
            print("HLO wire lint:")
            report["hlo_lint"] = run_hlo_lint()

    n_violations = sum(
        report.get(k, {}).get("violations", 0)
        for k in (
            "schedule_verification", "hlo_lint", "spmd_lint", "protocol",
        )
    )
    report["ok"] = n_violations == 0

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.json}")

    if not report["ok"]:
        print(f"FAILED: {n_violations} violation(s)")
        return 1
    print("all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
