"""Grid-sweep verification driver: ``python -m repro.analysis``.

Runs both static-analysis passes and emits the ``BENCH_7.json``
verification table:

1. **Schedule sweep** — every registered engine x the grid matrix
   (degenerate ``n=1``/``ppn=1`` grids, prime node counts, ragged
   payloads, chunk depths) through the four schedule-verifier passes.
   Engines without a schedule builder (the native psum fallbacks) are
   reported as ``native`` rows — a single native collective has no
   message schedule to verify.
2. **HLO wire-lint** — compiles the compressed fused-bucket gradient
   sync on 8 virtual CPU devices and runs the wire-dtype,
   collective-count and stable-lowering rules over the jaxpr and the
   optimized HLO.

Exits non-zero on any violation, so CI can gate on it::

    PYTHONPATH=src python -m repro.analysis --json reports/BENCH_7.json

``--skip-hlo`` runs only the (fast, jax-free) schedule sweep;
``--skip-schedules`` only the lint.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# the HLO pass compiles on virtual CPU devices: the flag must be set
# before anything imports jax, which is why this module (and the whole
# analysis package) keeps jax out of module scope
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def run_schedule_sweep() -> dict:
    from repro.core import comm

    from . import schedule_verifier as sv

    rows = []
    per_engine: dict[str, dict] = {}
    for key in sorted(comm.registered_engines()):
        collective, name = key.split(":", 1)
        spec = comm.get_engine(name, collective)
        reports = sv.verify_spec_grid(spec)
        n_bad = sum(1 for r in reports if not r.ok)
        n_native = sum(
            1 for r in reports if any(n.startswith("native") for n in r.notes)
        )
        n_skipped = sum(
            1 for r in reports if any(n.startswith("skipped") for n in r.notes)
        )
        per_engine[key] = {
            "cells": len(reports),
            "verified": len(reports) - n_bad - n_native - n_skipped,
            "native": n_native,
            "skipped_below_min_grid": n_skipped,
            "violations": n_bad,
        }
        rows.extend(r.to_row() for r in reports)
        status = "FAIL" if n_bad else "ok"
        print(
            f"  {key:28s} {per_engine[key]['verified']:4d} verified "
            f"{n_native:4d} native {n_skipped:4d} skipped "
            f"{n_bad:3d} violations  {status}"
        )
    n_violations = sum(e["violations"] for e in per_engine.values())
    return {
        "grid_matrix": [list(g) for g in sv.GRID_MATRIX],
        "payload_elems": list(sv.PAYLOAD_ELEMS),
        "engines": per_engine,
        "cells": len(rows),
        "violations": n_violations,
        "rows": rows,
    }


def run_hlo_lint() -> dict:
    """Compile the compressed fused-bucket grad sync and lint its wire."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.core import comm, grad_sync
    from repro.launch.mesh import make_mesh

    from . import hlo_lint

    mesh = make_mesh((2, 4), ("pod", "data"))
    shapes = [(64 + 32 * i,) for i in range(3)]
    payload_elems = sum(s[0] for s in shapes)

    def compiled(bits):
        policy = comm.CommPolicy(
            algorithm="nap", mean=True, compress_bits=bits
        )

        def f(*leaves):
            topo = comm.Topology.from_mesh(mesh)
            ctx = comm.CommContext(topo, policy)
            plan = grad_sync.plan_for_tree(
                [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes],
                cfg=policy, topology=topo,
            )
            out = grad_sync.sync_with_context(list(leaves), ctx, plan=plan)
            return jnp.concatenate(out)

        args = [jnp.zeros(s, jnp.float32) for s in shapes]
        g = compat.shard_map(
            f, mesh=mesh,
            in_specs=tuple(P() for _ in args),
            out_specs=P(),
            check_vma=False,
        )
        return g, args

    rows = []

    def record(context: str, violations) -> None:
        for v in violations:
            rows.append({"context": context, **v.to_row()})
        status = "FAIL" if violations else "ok"
        print(f"  {context:42s} {len(violations):2d} violations  {status}")

    for bits in (8, 4):
        g, args = compiled(bits)
        jaxpr = str(jax.make_jaxpr(g)(*args))
        record(
            f"jaxpr[bits={bits}] pallas_call budget",
            hlo_lint.lint_collective_counts(jaxpr, {"pallas_call": 4}),
        )
        hlo = jax.jit(g).lower(*args).compile().as_text()
        record(
            f"hlo[bits={bits}] compressed wire",
            hlo_lint.lint_compressed_wire(
                hlo, bits=bits, payload_elems=payload_elems, ppn=4
            ),
        )
    g, args = compiled(8)
    record(
        "stable lowering (no silent recompile)",
        hlo_lint.lint_stable_lowering(g, *args),
    )
    return {"rows": rows, "violations": len(rows)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis", description=__doc__
    )
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the BENCH_7 verification table here")
    ap.add_argument("--skip-hlo", action="store_true",
                    help="schedule sweep only (fast, jax-free)")
    ap.add_argument("--skip-schedules", action="store_true",
                    help="HLO lint only")
    args = ap.parse_args(argv)

    report: dict = {"bench": "BENCH_7", "ok": True}
    if not args.skip_schedules:
        print("schedule verification sweep:")
        report["schedule_verification"] = run_schedule_sweep()
    if not args.skip_hlo:
        print("HLO wire lint:")
        report["hlo_lint"] = run_hlo_lint()

    n_violations = sum(
        report.get(k, {}).get("violations", 0)
        for k in ("schedule_verification", "hlo_lint")
    )
    report["ok"] = n_violations == 0

    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.json}")

    if not report["ok"]:
        print(f"FAILED: {n_violations} violation(s)")
        return 1
    print("all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
