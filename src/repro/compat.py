"""Version-compat shims for the installed jax.

The repo targets the modern public API (``jax.shard_map``,
``jax.sharding.AxisType``); older installs (0.4.x) expose the same
functionality under experimental names and without explicit axis types.
Everything that needs one of the moved symbols imports it from here so
version probing lives in exactly one place.

Exports:

* :func:`shard_map` — keyword-compatible with ``jax.shard_map``; the
  new-API-only ``check_vma`` argument is translated (or dropped) for the
  experimental fallback.
* :func:`mesh_axis_types_kwargs` — ``{"axis_types": (Auto,)*n}`` when the
  install supports explicit axis types, else ``{}``.
* :func:`normalize_cost_analysis` — ``Compiled.cost_analysis()`` returned
  a one-element list of dicts on old jax; always returns the dict.
"""

from __future__ import annotations

import jax

__all__ = [
    "shard_map",
    "axis_size",
    "mesh_axis_types_kwargs",
    "normalize_cost_analysis",
]

try:  # jax >= 0.6: public AxisType enum
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    _AxisType = None


def mesh_axis_types_kwargs(n_axes: int) -> dict:
    """Mesh(..., **mesh_axis_types_kwargs(len(axes))) on any jax."""
    if _AxisType is None:
        return {}
    return {"axis_types": (_AxisType.Auto,) * n_axes}


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.6: experimental module, check_rep instead of check_vma
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
        if check_vma is not None:
            kwargs.setdefault("check_rep", check_vma)
        kwargs.setdefault("check_rep", False)
        return _shard_map_exp(
            f, mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )


if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:  # jax < 0.5: psum of a concrete 1 folds to the static axis size
    def axis_size(name) -> int:
        return jax.lax.psum(1, name)


def normalize_cost_analysis(cost) -> dict:
    """``Compiled.cost_analysis()`` as a dict on every jax version."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost
